"""Training substrate: optimizers, data pipeline, checkpoint/restore,
fault tolerance (resume, preemption, stragglers), gradient compression."""
import os
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.data import SyntheticLM, TokenFileDataset
from repro.data.pipeline import write_token_file
from repro.optim import adafactor, adamw, cosine_warmup
from repro.optim.adamw import apply_updates
from repro.optim.grad_compress import compress_decompress, init_error_state
from repro.train import Trainer, TrainerConfig, build, checkpoint


class TestOptimizers:
    def _quad_losses(self, opt, steps=60):
        w = jnp.asarray([3.0, -2.0, 1.5])
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        losses = []
        for _ in range(steps):
            g = jax.grad(lambda p: jnp.sum((p["w"] - w) ** 2))(params)
            upd, state = opt.update(g, state, params)
            params = apply_updates(params, upd)
            losses.append(float(jnp.sum((params["w"] - w) ** 2)))
        return losses

    def test_adamw_converges(self):
        losses = self._quad_losses(adamw(0.05, weight_decay=0.0))
        assert losses[-1] < 0.05 * losses[0]

    def test_adamw8bit_converges(self):
        losses = self._quad_losses(adamw(0.05, weight_decay=0.0, quantize_moments=True))
        assert losses[-1] < 0.1 * losses[0]

    def test_adafactor_converges(self):
        losses = self._quad_losses(adafactor(0.3))
        assert losses[-1] < 0.2 * losses[0]

    def test_adafactor_factored_state_is_small(self):
        opt = adafactor(0.01)
        params = {"w": jnp.zeros((256, 512))}
        state = opt.init(params)
        n = sum(x.size for x in jax.tree.leaves(state["mu"]))
        assert n == 256 + 512  # factored: O(n+m), not O(nm)

    def test_schedule(self):
        lr = cosine_warmup(1.0, warmup=10, total=100)
        assert float(lr(0)) == 0.0
        assert float(lr(10)) == pytest.approx(1.0)
        assert float(lr(100)) < float(lr(50)) < float(lr(10))


class TestGradCompression:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 1000), n=st.integers(3, 2000))
    def test_quantization_error_bounded(self, seed, n):
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        err = jnp.zeros((n,), jnp.float32)
        ghat, new_err = compress_decompress(g, err)
        blockmax = float(jnp.max(jnp.abs(g)))
        assert float(jnp.max(jnp.abs(ghat - g))) <= blockmax / 127.0 + 1e-6

    def test_error_feedback_preserves_sum(self):
        """With EF, the *cumulative* compressed signal tracks the true
        cumulative gradient (bounded residual)."""
        rng = np.random.default_rng(0)
        err = jnp.zeros((64,), jnp.float32)
        tot_true = np.zeros(64)
        tot_comp = np.zeros(64)
        for i in range(50):
            g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
            ghat, err = compress_decompress(g, err)
            tot_true += np.asarray(g)
            tot_comp += np.asarray(ghat)
        resid = np.abs(tot_true - tot_comp)
        assert resid.max() < 0.2, resid.max()   # bounded, does not grow in t

    def test_sgd_with_compression_converges(self):
        w = jnp.asarray(np.linspace(-2, 2, 32).astype(np.float32))
        params = jnp.zeros(32)
        err = jnp.zeros(32)
        for _ in range(400):
            g = 2 * (params - w)
            ghat, err = compress_decompress(g, err)
            params = params - 0.05 * ghat
        assert float(jnp.max(jnp.abs(params - w))) < 1e-2


class TestDataPipeline:
    def test_synthetic_deterministic_and_resumable(self):
        a = SyntheticLM(100, 8, 2, seed=5)
        batches = [a.next_batch() for _ in range(4)]
        st8 = a.state()
        b5 = a.next_batch()
        b = SyntheticLM(100, 8, 2, seed=0)
        b.restore(st8)
        np.testing.assert_array_equal(b.next_batch()["tokens"], b5["tokens"])

    def test_token_file_dataset(self, tmp_path):
        toks = np.arange(9 * 10, dtype=np.uint16)
        path = write_token_file(tmp_path / "toks.bin", toks)
        ds = TokenFileDataset(str(path), seq_len=8, batch_size=2)
        b = ds.next_batch()
        np.testing.assert_array_equal(b["tokens"][0], np.arange(8))
        np.testing.assert_array_equal(b["targets"][0], np.arange(1, 9))

    def test_token_file_sharding_disjoint(self, tmp_path):
        toks = np.arange(9 * 8, dtype=np.uint16)
        path = write_token_file(tmp_path / "t.bin", toks)
        d0 = TokenFileDataset(str(path), 8, 2, shard_index=0, num_shards=2)
        d1 = TokenFileDataset(str(path), 8, 2, shard_index=1, num_shards=2)
        t0 = set(map(tuple, d0.next_batch()["tokens"]))
        t1 = set(map(tuple, d1.next_batch()["tokens"]))
        assert not (t0 & t1)


@pytest.fixture(scope="module")
def tiny():
    cfg = configs.get_smoke_config("qwen2.5-3b")
    state, step_fn = build(cfg, optimizer="adamw", lr=1e-3)
    return cfg, state, step_fn


class TestCheckpoint:
    def test_roundtrip(self, tiny, tmp_path):
        cfg, state, _ = tiny
        checkpoint.save(tmp_path, 7, state, extras={"x": 1})
        got, extras, step = checkpoint.restore(tmp_path, 7, state)
        assert step == 7 and extras == {"x": 1}
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_gc_keeps_last(self, tiny, tmp_path):
        cfg, state, _ = tiny
        for s in (1, 2, 3, 4):
            checkpoint.save(tmp_path, s, {"a": jnp.ones(3)}, keep_last=2)
        assert checkpoint.latest_step(tmp_path) == 4
        import pathlib

        assert len(list(pathlib.Path(tmp_path).glob("step_*"))) == 2

    def test_async_save(self, tiny, tmp_path):
        t = checkpoint.save(tmp_path, 9, {"a": jnp.ones(3)}, async_write=True)
        t.join()
        assert checkpoint.latest_step(tmp_path) == 9


class TestTrainerFaultTolerance:
    def test_loss_decreases(self, tiny):
        cfg, state, step_fn = tiny

        class Learnable:
            """Fully predictable stream: next token = (token + 1) % V."""

            def next_batch(self):
                base = np.arange(17)[None, :] % cfg.vocab_size
                toks = np.repeat(base, 2, axis=0).astype(np.int32)
                return {"tokens": toks[:, :-1], "targets": toks[:, 1:],
                        "loss_mask": np.ones((2, 16), np.float32)}

        tr = Trainer(state, step_fn, Learnable(),
                     TrainerConfig(total_steps=30, log_every=1))
        res = tr.run()
        first = np.mean([h["loss"] for h in res["history"][:5]])
        last = np.mean([h["loss"] for h in res["history"][-5:]])
        assert last < 0.7 * first, (first, last)

    def test_resume_after_crash(self, tiny, tmp_path):
        cfg, state, step_fn = tiny
        ds = SyntheticLM(cfg.vocab_size, 16, 2, seed=2)
        tr = Trainer(state, step_fn, ds,
                     TrainerConfig(total_steps=6, ckpt_dir=str(tmp_path), ckpt_every=3))
        tr.run()
        # "crash": brand-new trainer, fresh state, same ckpt dir
        state2, step_fn2 = build(cfg, optimizer="adamw", lr=1e-3, seed=123)
        ds2 = SyntheticLM(cfg.vocab_size, 16, 2, seed=2)
        tr2 = Trainer(state2, step_fn2, ds2,
                      TrainerConfig(total_steps=10, ckpt_dir=str(tmp_path), ckpt_every=3))
        res = tr2.run()
        assert res["final_step"] == 10
        assert ds2.step == 10  # data cursor restored + advanced
        assert int(np.asarray(tr2.state["step"])) == 10

    def test_preemption_signal_saves_and_exits(self, tiny, tmp_path):
        cfg, state, step_fn = tiny
        ds = SyntheticLM(cfg.vocab_size, 16, 2, seed=2)
        tr = Trainer(state, step_fn, ds,
                     TrainerConfig(total_steps=1000, ckpt_dir=str(tmp_path),
                                   ckpt_every=1000))
        def preempt():
            time.sleep(1.5)
            tr._stop = True   # equivalent to the SIGTERM handler body
        th = threading.Thread(target=preempt)
        th.start()
        res = tr.run()
        th.join()
        assert res["interrupted"]
        assert res["final_step"] < 1000
        assert checkpoint.latest_step(tmp_path) == res["final_step"]

    def test_straggler_detection(self):
        """Watchdog flags steps slower than factor x rolling median; use a
        synthetic step so baseline timing is controlled."""
        ds = SyntheticLM(16, 4, 1, seed=0)
        calls = {"n": 0}

        def fake_step(state, batch):
            calls["n"] += 1
            time.sleep(0.25 if calls["n"] == 12 else 0.01)
            return state, {"loss": jnp.float32(1.0)}

        tr = Trainer({}, fake_step, ds,
                     TrainerConfig(total_steps=15, straggler_factor=3.0),
                     jit=False)
        res = tr.run()
        assert res["stragglers"] >= 1
