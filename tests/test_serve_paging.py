"""Paged KV-cache pool: PagePool allocator/refcount/eviction invariants
(orphaned-chain cleanup and cross-trace accounting included),
prefix-cache hit/miss accounting on Scheduler stats, the page-capacity
ValueError contract, and the no-cross-request-leakage regression for
refcounted pages."""
import dataclasses
from collections import Counter

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import lm
from repro.serve import (
    Engine,
    PagePool,
    Request,
    Scheduler,
    check_page_capacity,
    pages_needed,
    prefix_page_hashes,
)

VOCAB = 512


def _mk(arch="qwen2.5-3b", cache="float32"):
    """Smoke config with a LOSSLESS cache dtype so prefix reuse is
    active (reused pages must hold exactly what the reference prefill
    attends at compute precision)."""
    cfg = configs.get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, cache_dtype=cache)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prefix_reqs(rng, n, prefix_len, tail_lens, n_tokens=4, arrivals=None):
    pre = rng.integers(0, VOCAB, prefix_len).astype(np.int32)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, VOCAB, tail_lens[i % len(tail_lens)]).astype(np.int32)
        reqs.append(Request(
            prompt=np.concatenate([pre, tail]), n_tokens=n_tokens,
            arrival=0 if arrivals is None else arrivals[i % len(arrivals)],
        ))
    return reqs


class TestPagePool:
    def test_allocate_free_refcount_roundtrip(self):
        pool = PagePool(n_pages=9, page_size=8)
        assert pool.usable_pages == 8 and pool.available() == 8
        pages = pool.allocate(3)
        assert 0 not in pages                      # garbage page never handed out
        assert len(set(pages)) == 3
        assert all(pool.refcount(p) == 1 for p in pages)
        assert pool.available() == 5
        pool.release(pages)
        assert pool.available() == 8               # unindexed pages free instantly
        with pytest.raises(ValueError):
            pool.release([pages[0]])               # double release

    def test_exhaustion_is_runtime_error(self):
        pool = PagePool(n_pages=4, page_size=8)
        pool.allocate(3)
        with pytest.raises(RuntimeError, match="exhausted"):
            pool.allocate(1)

    def test_cached_prefix_pages_hit_after_release_then_evict_lru(self):
        pool = PagePool(n_pages=6, page_size=4)
        prompt = np.arange(9, dtype=np.int32)
        hashes = prefix_page_hashes(prompt, 4)
        assert len(hashes) == 2                    # pages fully covered by 9 tokens
        pages = pool.allocate(2)
        pool.register_prefix(hashes, pages)
        pool.release(pages)                        # -> CACHED, still hittable
        got, hs = pool.match_prefix(prompt)
        assert got == pages and hs == hashes
        # Allocation pressure evicts LRU cached pages and drops index entries.
        pool.allocate(5)
        assert pool.stats.evictions == 2
        assert pool.match_prefix(prompt)[0] == []

    def test_match_stops_at_first_miss_and_caps_short_of_prompt(self):
        pool = PagePool(n_pages=8, page_size=4)
        prompt = np.arange(12, dtype=np.int32)     # 3 fully covered pages
        hashes = prefix_page_hashes(prompt, 4)
        assert len(hashes) == 3
        pages = pool.allocate(2)
        pool.register_prefix(hashes[:2], pages)
        # Page-aligned prompt: the match is capped one token short so the
        # tail prefill is never empty (the last page must be recomputed
        # to produce first-token logits).
        got, hs = pool.match_prefix(prompt)
        assert got == pages and len(hs) == 2
        # Chain hashing: losing the FIRST page makes the second unreachable.
        pool.release(pages[:1])
        pool.allocate(6)      # 5 free + 1 eviction: the cached first page
        assert pool.stats.evictions == 1
        assert pool.match_prefix(prompt)[0] == []

    @given(
        page_size=st.integers(1, 8),
        plen=st.integers(1, 40),
        n_tokens=st.integers(1, 40),
    )
    @settings(max_examples=60, deadline=None)
    def test_pages_needed_covers_every_written_position(self, page_size, plen, n_tokens):
        """pages_needed must cover prompt positions [0, P) and decode
        writes [P, P + n_tokens - 1) — and not a page more."""
        written = plen + n_tokens - 1
        need = pages_needed(plen, n_tokens, page_size)
        assert need * page_size >= written
        assert (need - 1) * page_size < written

    def test_unref_rolls_back_pin_and_hit_stats(self):
        """A failed admission unpins its matched pages and reverses the
        hit counters the ref charged — the pages return to CACHED and
        remain hittable."""
        pool = PagePool(n_pages=6, page_size=4)
        prompt = np.arange(9, dtype=np.int32)
        pages = pool.allocate(2)
        pool.register_prefix(prefix_page_hashes(prompt, 4), pages)
        pool.release(pages)                        # -> CACHED
        pool.ref(pages)
        pool.unref(pages)
        assert pool.stats.prefix_hits == 0
        assert pool.stats.prefix_hit_tokens == 0
        assert all(pool.refcount(p) == 0 for p in pages)
        assert pool.match_prefix(prompt)[0] == pages

    def test_evicted_parent_frees_cached_child_accounting(self):
        """A cached child behind an evicted parent is unreachable by
        construction (chain hashing) — evicting the parent must free the
        orphan's accounting too, not leave it squatting in the LRU."""
        pool = PagePool(n_pages=8, page_size=4)
        prompt = np.arange(13, dtype=np.int32)        # 3 indexable pages
        hashes = prefix_page_hashes(prompt, 4)
        pages = pool.allocate(3)
        pool.register_prefix(hashes, pages)
        pool.release(pages)                           # all 3 -> CACHED
        assert pool.available() == 7
        # One eviction under pressure reclaims the parent AND its two
        # orphaned descendants — the free list regains all three.
        got = pool.allocate(5)                        # 4 free + parent evict
        assert pool.stats.evictions == 3              # parent + 2 orphans
        assert pool.stats.orphaned_live == 0
        assert pool.match_prefix(prompt)[0] == []
        assert pool.available() + pool.live_pages == pool.usable_pages
        assert len(set(got)) == 5 and 0 not in got

    def test_evicted_parent_unindexes_live_child_which_frees_privately(self):
        """A LIVE child behind an evicted parent loses its index entry
        (it could never be matched again) and frees like a private page
        when its tenant retires — it must NOT re-enter the LRU."""
        pool = PagePool(n_pages=8, page_size=4)
        prompt = np.arange(12, dtype=np.int32)
        hashes = prefix_page_hashes(prompt, 4)        # 3 chain hashes
        pages = pool.allocate(2)
        pool.register_prefix(hashes[:2], pages)
        pool.release(pages[:1])                       # parent CACHED, child LIVE
        pool.allocate(6)                              # 5 free + parent evict
        assert pool.stats.evictions == 1
        assert pool.stats.orphaned_live == 1
        assert pool.match_prefix(prompt)[0] == []
        avail_before = pool.available()
        pool.release(pages[1:])                       # orphaned live child
        assert pool.available() == avail_before + 1   # straight to free list
        assert pool.stats.cached_pages == 0           # never re-cached
        assert pool.available() + pool.live_pages == pool.usable_pages

    def test_long_chain_orphan_cleanup_is_iterative(self):
        """Evicting the root of a thousands-deep prefix chain must not
        recurse once per page (RecursionError) — the orphan walk is a
        worklist."""
        pool = PagePool(n_pages=3002, page_size=1)
        prompt = np.arange(3001, dtype=np.int32)   # 3000-hash chain
        hashes = prefix_page_hashes(prompt, 1)[:3000]
        pages = pool.allocate(3000)
        pool.register_prefix(hashes, pages)
        pool.release(pages)                        # whole chain CACHED
        got = pool.allocate(3001)                  # evicts the root + orphans
        assert len(got) == 3001
        assert pool.stats.evictions == 3000
        assert pool.match_prefix(prompt)[0] == []
        assert pool.available() + pool.live_pages == pool.usable_pages

    def test_cross_trace_hit_counters_and_unref_rollback(self):
        """Hits on pages filled by an EARLIER trace count as cross-trace
        (the persistent-session warm signal); intra-trace hits do not;
        unref rolls the cross-trace counters back too."""
        pool = PagePool(n_pages=6, page_size=4)
        prompt = np.arange(9, dtype=np.int32)
        hashes = prefix_page_hashes(prompt, 4)
        pool.begin_trace()
        pages = pool.allocate(2)
        pool.register_prefix(hashes, pages)
        got, _ = pool.match_prefix(prompt)
        pool.ref(got)                                 # same trace: intra
        assert pool.stats.prefix_hits == 2
        assert pool.stats.cross_trace_hits == 0
        pool.release(got)
        pool.release(pages)
        pool.begin_trace()
        got, _ = pool.match_prefix(prompt)
        pool.ref(got)                                 # next trace: cross
        assert pool.stats.cross_trace_hits == 2
        assert pool.stats.cross_trace_hit_tokens == 8
        pool.unref(got)                               # failed admission
        assert pool.stats.cross_trace_hits == 0
        assert pool.stats.cross_trace_hit_tokens == 0
        assert pool.stats.prefix_hits == 2            # trace-1 hits remain

    @given(
        seed=st.integers(0, 10_000),
        n_ops=st.integers(5, 60),
    )
    @settings(max_examples=40, deadline=None)
    def test_page_accounting_conserved_across_traces(self, seed, n_ops):
        """Conservation law of the pool: every usable page is exactly
        one of allocatable (``available()``) or live (refcount > 0) —
        under random cross-trace sequences of admissions (match + ref +
        allocate + register, scheduler-style), rollbacks, retirements
        and the evictions (with orphan cleanup) they trigger."""
        rng = np.random.default_rng(seed)
        pool = PagePool(n_pages=7, page_size=4)
        # A few prefix families so traces collide, extend and re-fill
        # each other's chains.
        fams = [np.arange(24, dtype=np.int32) + 100 * f for f in range(3)]
        tenants = []

        def check():
            assert pool.available() + pool.live_pages == pool.usable_pages
            # Every page's refcount equals the number of tenants naming
            # it (shared prefix pages are held multiply — that is the
            # point), and the garbage page is never handed out.
            held = Counter(p for pages in tenants for p in pages)
            assert 0 not in held
            for p, k in held.items():
                assert pool.refcount(p) == k
            assert pool.live_pages == len(held)

        pool.begin_trace()
        for _ in range(n_ops):
            op = rng.integers(4)
            if op == 0:                               # trace boundary
                pool.begin_trace()
            elif op == 1:                             # admission attempt
                fam = fams[rng.integers(len(fams))]
                plen = int(rng.integers(1, 25))
                n_tokens = int(rng.integers(1, 6))
                prompt = fam[:plen]
                need = pages_needed(plen, n_tokens, 4)
                matched, hashes = pool.match_prefix(prompt)
                pool.ref(matched)
                fresh_needed = need - len(matched)
                if fresh_needed > pool.available():
                    pool.unref(matched)               # rollback path
                else:
                    fresh = pool.allocate(fresh_needed)
                    pages = matched + fresh
                    if len(hashes) > len(matched):
                        pool.register_prefix(
                            hashes[len(matched):],
                            pages[len(matched):len(hashes)],
                            parent=hashes[len(matched) - 1] if matched else None,
                        )
                    tenants.append(pages)
            elif op >= 2 and tenants:                 # retirement
                pool.release(tenants.pop(int(rng.integers(len(tenants)))))
            check()
        # Retire everything: the pool must account for every page again.
        while tenants:
            pool.release(tenants.pop())
            check()
        assert pool.available() == pool.usable_pages

    def test_chain_hashes_disambiguate_equal_pages(self):
        """Two prompts sharing page 1 CONTENT but not page 0 must not
        collide: a chain hash names the whole prefix."""
        a = np.concatenate([np.zeros(4, np.int32), np.ones(4, np.int32)])
        b = np.concatenate([np.full(4, 7, np.int32), np.ones(4, np.int32)])
        ha, hb = prefix_page_hashes(a, 4), prefix_page_hashes(b, 4)
        assert ha[0] != hb[0] and ha[1] != hb[1]


class TestCapacityContract:
    def test_check_page_capacity_value_error(self):
        with pytest.raises(ValueError) as ei:
            check_page_capacity(prompt_len=30, n_tokens=8, page_size=8,
                                usable_pages=4)
        msg = str(ei.value)
        assert "30" in msg and "8" in msg and "page" in msg
        check_page_capacity(30, 3, 8, 4)           # 4 pages cover 32 positions

    def test_scheduler_rejects_oversize_for_pool_not_just_max_len(self):
        """A request that fits max_len but not the page pool raises the
        same ValueError capacity contract as serve.check_capacity."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=64, page_size=8,
                          n_pages=4)               # 3 usable pages = 24 positions
        rng = np.random.default_rng(0)
        bad = Request(prompt=rng.integers(0, VOCAB, 20).astype(np.int32),
                      n_tokens=8)
        with pytest.raises(ValueError, match="page-pool capacity"):
            sched.serve([bad])
        ok = Request(prompt=bad.prompt[:20], n_tokens=5)   # 24 positions fit
        res = sched.serve([ok])[0]
        assert res.tokens.size == 25

    def test_transient_exhaustion_queues_instead_of_raising(self):
        """Enough pages for each request alone but not both at once:
        the second request waits for the first's retirement (no error,
        both served, tokens exact)."""
        cfg, params = _mk()
        eng = Engine(cfg, params, max_len=32)
        sched = Scheduler(cfg, params, max_slots=2, max_len=32, page_size=8,
                          n_pages=4, prefix_reuse=False)   # 3 usable pages
        rng = np.random.default_rng(1)
        reqs = [Request(prompt=rng.integers(0, VOCAB, 12).astype(np.int32),
                        n_tokens=5) for _ in range(2)]     # 2 pages each
        results = sched.serve(reqs)
        for req, res in zip(reqs, results):
            ref = eng.generate(req.prompt[None], n_tokens=5,
                               request_ids=[res.rid])
            np.testing.assert_array_equal(ref.tokens[0], res.tokens)
        assert results[1].admitted_step > results[0].admitted_step


class TestPrefixAccounting:
    def test_hit_miss_counters_on_scheduler_stats(self):
        """16 requests over one 16-token system prefix, page_size 8: the
        first admission fills the 2 prefix pages (misses), every later
        one reuses them (hits), including after retirements (cached
        pages)."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=64, page_size=8)
        rng = np.random.default_rng(2)
        reqs = _prefix_reqs(rng, 8, prefix_len=16, tail_lens=[2, 3, 5])
        results = sched.serve(reqs)
        stats = sched.last_stats
        assert stats.prefix_reuse_active
        pg = stats.paging
        assert pg["prefix_hits"] == 14              # 7 later requests x 2 pages
        assert pg["prefix_hit_tokens"] == 14 * 8
        assert pg["prefix_misses"] >= 2             # first fill of the prefix
        assert pg["evictions"] == 0
        assert pg["peak_pages_in_use"] <= pg["n_pages"]
        hits = [r.prefix_hit_tokens for r in results]
        assert hits[0] == 0 and all(h == 16 for h in hits[1:])

    def test_prefix_reuse_is_token_exact_and_flag_gates_it(self):
        cfg, params = _mk()
        rng = np.random.default_rng(3)
        reqs = _prefix_reqs(rng, 6, prefix_len=24, tail_lens=[2, 4])
        on = Scheduler(cfg, params, max_slots=2, max_len=64, page_size=8)
        off = Scheduler(cfg, params, max_slots=2, max_len=64, page_size=8,
                        prefix_reuse=False)
        r_on, r_off = on.serve(reqs), off.serve(reqs)
        for a, b in zip(r_on, r_off):
            np.testing.assert_array_equal(a.tokens, b.tokens)
        assert on.last_stats.paging["prefix_hits"] > 0
        assert off.last_stats.paging["prefix_hits"] == 0
        assert not off.last_stats.prefix_reuse_active

    def test_identical_prompts_in_one_burst_split_then_hit(self):
        """Two identical prompts arriving at the same step: the second's
        prefix pages are pending fill by the first's burst, so the burst
        SPLITS (two prefill programs) and the second request still hits
        the just-filled pages — exactly, and with no self-read of
        unfilled pages."""
        cfg, params = _mk()
        eng = Engine(cfg, params, max_len=64)
        sched = Scheduler(cfg, params, max_slots=2, max_len=64, page_size=8)
        rng = np.random.default_rng(4)
        p = rng.integers(0, VOCAB, 20).astype(np.int32)
        reqs = [Request(prompt=p, n_tokens=6, rid=i) for i in range(2)]
        results = sched.serve(reqs)
        for req, res in zip(reqs, results):
            ref = eng.generate(req.prompt[None], n_tokens=6,
                               request_ids=[res.rid])
            np.testing.assert_array_equal(ref.tokens[0], res.tokens)
        assert sched.last_stats.prefill_batches == 2
        assert results[1].prefix_hit_tokens == 16   # 2 of its pages reused


class TestNoCrossRequestLeakage:
    def test_recycled_pages_never_readable_by_later_tenant(self):
        """Regression: a retired request's pages are reallocated to later
        tenants, but masked reads + garbage-page writes mean the probe's
        tokens are identical to serving it into a never-used pool — for
        every slot/page placement a warm-up tenant can force."""
        cfg, params = _mk()
        rng = np.random.default_rng(5)
        probe = Request(prompt=rng.integers(0, VOCAB, 13).astype(np.int32),
                        n_tokens=6)
        alone = Scheduler(cfg, params, max_slots=1, max_len=64,
                          page_size=8).serve(
            [dataclasses.replace(probe, rid=9)]
        )[0]
        for warm_len in (5, 23, 37):   # different page footprints
            warm = Request(
                prompt=rng.integers(0, VOCAB, warm_len).astype(np.int32),
                n_tokens=9,
            )
            sched = Scheduler(cfg, params, max_slots=1, max_len=64,
                              page_size=8)
            _, again = sched.serve([warm, dataclasses.replace(probe, rid=9)])
            np.testing.assert_array_equal(alone.tokens, again.tokens)

    def test_refcounted_shared_pages_survive_one_tenants_retirement(self):
        """Two prefix-sharing requests with different lifetimes: the
        short one retires (dropping its refs) while the long one still
        decodes THROUGH the shared pages — and a third request admitted
        into the freed slot reuses them too.  All tokens exact."""
        cfg, params = _mk()
        eng = Engine(cfg, params, max_len=64)
        sched = Scheduler(cfg, params, max_slots=2, max_len=64, page_size=8)
        rng = np.random.default_rng(6)
        pre = rng.integers(0, VOCAB, 16).astype(np.int32)
        mk = lambda tail, n: Request(
            prompt=np.concatenate([pre, np.asarray(tail, np.int32)]), n_tokens=n
        )
        reqs = [mk([1, 2], 2), mk([3, 4, 5], 12), mk([6], 4)]
        for req, res in zip(reqs, sched.serve(reqs)):
            ref = eng.generate(req.prompt[None], n_tokens=req.n_tokens,
                               request_ids=[res.rid])
            np.testing.assert_array_equal(ref.tokens[0], res.tokens)
        assert sched.last_stats.paging["prefix_hits"] >= 4

    def test_poisoned_free_pages_do_not_change_output(self):
        """Belt and braces for the masking argument: serve through a pool
        whose every page was poisoned with huge values first — if any
        unwritten/foreign row were ever readable, attention over 1e9
        keys would derail the tokens."""
        cfg, params = _mk()
        sched = Scheduler(cfg, params, max_slots=2, max_len=32, page_size=8)
        rng = np.random.default_rng(7)
        reqs = [Request(prompt=rng.integers(0, VOCAB, p).astype(np.int32),
                        n_tokens=4, rid=i) for i, p in enumerate([5, 9])]
        clean = sched.serve(reqs)

        poisoned = Scheduler(cfg, params, max_slots=2, max_len=32, page_size=8)
        real_init = lm.init_paged_pool

        def poisoned_init(cfg_, n_slots, n_pages, page_size, **kw):
            import jax.numpy as jnp
            pool = real_init(cfg_, n_slots, n_pages, page_size, **kw)
            return jax.tree.map(lambda a: jnp.full_like(a, 1e9), pool)

        lm.init_paged_pool = poisoned_init
        try:
            dirty = poisoned.serve(reqs)
        finally:
            lm.init_paged_pool = real_init
        for c, d in zip(clean, dirty):
            np.testing.assert_array_equal(c.tokens, d.tokens)
